"""Co-occurrence network -> GIN: the paper's output as a first-class graph.

    PYTHONPATH=src python examples/cooccur_to_gnn.py

Builds a keyword co-occurrence network over a synthetic CSL-like corpus
with the optimized algorithm (Algorithm 3), converts it to an edge index,
and trains the assigned ``gin-tu`` architecture on it to classify terms
into frequency bands (a stand-in for topic labels) — demonstrating the
paper's technique integrated with the GNN substrate (DESIGN.md §5).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import bfs_construct, pack_docs, to_edge_index
from repro.data import synthetic_csl
from repro.models import gnn as G
from repro.train import make_optimizer, make_train_step


def main():
    vocab, n_docs = 512, 4000
    docs = synthetic_csl(n_docs, vocab, seed=0)
    index = pack_docs(docs, vocab)
    df = np.asarray(index.doc_freq)

    # build the network from the top high-frequency seeds (paper §4)
    seeds = np.argsort(-df)[:8].astype(np.int32)
    pad = np.full((16,), -1, np.int32)
    pad[:8] = seeds
    net = bfs_construct(index, jnp.asarray(pad), depth=3, topk=12, beam=16)
    ei, ew = to_edge_index(net)
    print(f"co-occurrence network: {ei.shape[1] // 2} undirected edges")

    # node features: degree + log-df; labels: df quartile band
    x = np.zeros((vocab, 8), np.float32)
    deg = np.bincount(ei[0], minlength=vocab).astype(np.float32)
    x[:, 0] = deg / max(deg.max(), 1)
    x[:, 1] = np.log1p(df) / np.log1p(df.max())
    x[:, 2:] = np.random.default_rng(0).standard_normal((vocab, 6)) * 0.1
    labels = np.digitize(df, np.percentile(df[df > 0], [25, 50, 75]))

    in_net = np.zeros(vocab, np.float32)
    in_net[np.unique(ei)] = 1.0                      # only network nodes count

    cfg = get_config("gin-tu")
    params = G.init_gin(cfg, jax.random.PRNGKey(0), 8, 4)
    opt = make_optimizer(cfg)
    step = jax.jit(make_train_step(cfg, lambda p, b: G.node_loss(cfg, p, b), opt))
    batch = {
        "x": jnp.asarray(x),
        "edge_src": jnp.asarray(ei[0], jnp.int32),
        "edge_dst": jnp.asarray(ei[1], jnp.int32),
        "labels": jnp.asarray(labels, jnp.int32),
        "label_mask": jnp.asarray(in_net),
    }
    state = opt.init(params)
    for s in range(30):
        params, state, m = step(params, state, batch)
        if s % 10 == 0 or s == 29:
            print(f"step {s:3d}  loss {float(m['loss']):.4f}  "
                  f"acc {float(m['acc']):.3f}")
    assert np.isfinite(float(m["loss"]))
    print("GIN trained on the co-occurrence network  [ok]")


if __name__ == "__main__":
    main()
