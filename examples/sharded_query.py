"""Device-mesh sharded queries through the string-level facade.

Forces an 8-device CPU host (the env var must be set before jax loads),
builds one single-device CoocIndex and one term-sharded over all 8
devices, and shows that ingest, BFS queries, scoped queries, and
full-network materialization answer IDENTICALLY — the sharded engine is
a bit-exact drop-in, it just executes across the mesh (on real hardware:
across accelerators).

    python examples/sharded_query.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

from repro.api import CoocIndex  # noqa: E402

TEXTS = [
    "the inverted index maps every term to its posting documents",
    "a co-occurrence network links terms that share documents",
    "real time construction keeps the network fresh under ingest",
    "term partitioned postings scale the index across devices",
    "each device counts against its local postings shard",
    "partial counts merge across the device mesh",
    "the merged network is bit exact against one device",
    "queries stream through the engine in micro batches",
]


def main():
    print(f"host devices: {len(jax.devices())}")
    plain = CoocIndex.from_texts(TEXTS, depth=2, topk=8, beam=16)
    sharded = CoocIndex(depth=2, topk=8, beam=16,
                        devices=len(jax.devices()))   # term-sharded mesh
    sharded.add_documents(TEXTS)
    print(f"sharded mesh: {dict(sharded.mesh.shape)}")

    # live ingest stays bit-exact: both see the new doc immediately
    fresh = ["fresh documents join the postings shards immediately"]
    plain.add_documents(fresh, source="fresh")
    sharded.add_documents(fresh, source="fresh")

    for seeds in (["index"], ["network", "device"]):
        a = plain.network(seeds)
        b = sharded.network(seeds)
        assert a == b, (seeds, a, b)
        top = sorted(a.items(), key=lambda kv: -kv[1])[:3]
        print(f"query {seeds}: {len(a)} edges, top {top}   [identical]")

    a = plain.network(["documents"], scope="fresh")
    b = sharded.network(["documents"], scope="fresh")
    assert a == b
    print(f"scoped query ('fresh'): {b}   [identical]")

    full_a = plain.full_network(k=4)
    full_b = sharded.full_network(k=4)
    assert full_a == full_b
    st = sharded.network_stats(k=4)
    print(f"full network: {st.n_nodes} nodes, {st.n_edges} edges, "
          f"density {st.density:.3f}   [identical]")
    print("sharded == single-device, bit for bit  [ok]")


if __name__ == "__main__":
    main()
