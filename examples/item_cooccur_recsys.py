"""Item-item co-occurrence retrieval for recsys (users-as-documents).

    PYTHONPATH=src python examples/item_cooccur_recsys.py

The paper's algorithm applied to the retrieval side of a recommender
(DESIGN.md §5): treat each user's interaction history as a "document" of
item ids; the inverted-index BFS then yields, per anchor item, the items
most co-consumed with it — a candidate generator.  A SASRec model then
re-ranks those candidates (the standard retrieve -> rank split).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, replace
from repro.core import bfs_construct, pack_docs
from repro.models import recsys as R


def main():
    rng = np.random.default_rng(0)
    n_users, n_items = 4000, 1000
    # users with taste clusters -> co-consumption structure
    n_clusters = 20
    item_cluster = rng.integers(0, n_clusters, n_items)
    histories = []
    for _ in range(n_users):
        c = rng.integers(0, n_clusters)
        in_c = np.where(item_cluster == c)[0]
        k = rng.integers(3, 12)
        hist = rng.choice(in_c, size=min(k, len(in_c)), replace=False)
        if rng.random() < 0.3:                      # some cross-cluster noise
            hist = np.concatenate([hist, rng.integers(0, n_items, 2)])
        histories.append(hist.tolist())

    index = pack_docs(histories, n_items)
    anchor = int(np.argmax(np.asarray(index.doc_freq)))

    # retrieve: co-consumption BFS around the anchor item
    pad = np.full((8,), -1, np.int32)
    pad[0] = anchor
    net = bfs_construct(index, jnp.asarray(pad), depth=2, topk=16, beam=16)
    cand = sorted({int(d) for d, ok in zip(np.asarray(net.dst),
                                           np.asarray(net.valid)) if ok}
                  | {int(s) for s, ok in zip(np.asarray(net.src),
                                             np.asarray(net.valid)) if ok}
                  - {anchor})
    print(f"anchor item {anchor} (cluster {item_cluster[anchor]}): "
          f"{len(cand)} co-occurrence candidates")
    same = np.mean([item_cluster[c] == item_cluster[anchor] for c in cand])
    print(f"candidate purity (same cluster as anchor): {same:.2f}")
    assert same > 0.5, "co-occurrence retrieval should surface the cluster"

    # rank: SASRec scores the candidates against a user's history
    cfg = replace(get_config("sasrec"), n_items=n_items, seq_len=16)
    params = R.init_params(cfg, jax.random.PRNGKey(0))
    user_hist = histories[0][:16]
    seq = np.zeros((1, 16), np.int32)
    seq[0, -len(user_hist):] = user_hist
    batch = {"seq": jnp.asarray(seq),
             "candidates": jnp.asarray(np.asarray(cand, np.int32))}
    scores = R.retrieval_fn(cfg, params, batch)
    order = np.argsort(-np.asarray(scores[0]))
    print("top-5 ranked candidates:", [cand[i] for i in order[:5]])
    print("retrieve (paper's algorithm) -> rank (SASRec)  [ok]")


if __name__ == "__main__":
    main()
