"""The paper's target scenario: a real-time co-occurrence query service.

    PYTHONPATH=src python examples/serve_realtime.py

Stands up the plan-aware CoocEngine over a CSL-scale-shaped corpus and
serves a HETEROGENEOUS burst — mixed QuerySpecs (different depth/topk/
beam/method) through one engine, results via futures — showing that the
per-plan executor cache compiles once per distinct plan, not per query.
Then ingests fresh documents and shows the next query reflecting them
immediately (the "real-time and dynamic characteristics" the paper
motivates), and finishes with the string-level CoocIndex facade.
"""
import numpy as np

from repro.api import CoocIndex
from repro.core import QueryContext, QuerySpec
from repro.data import synthetic_csl
from repro.serve import CoocEngine


def main():
    vocab, n_docs = 2048, 10000
    docs = synthetic_csl(n_docs, vocab, seed=0)
    ctx = QueryContext.from_docs(docs, vocab, capacity=n_docs + 4096)
    eng = CoocEngine(ctx, q_batch=8, on_overflow="grow")

    df = np.bincount(np.concatenate([np.unique(d) for d in docs]),
                     minlength=vocab)
    hot = np.argsort(-df)[:32]

    # a mixed workload: three query plans interleaved, one engine
    plans = [dict(depth=2, topk=12, beam=16),
             dict(depth=1, topk=24, beam=8),
             dict(depth=3, topk=6, beam=16, method="popcount")]
    futures = [eng.submit(QuerySpec(seeds=(int(t),), **plans[i % 3]))
               for i, t in enumerate(hot)]
    results = [f.result() for f in futures]
    st = eng.stats()
    print(f"{st.n} mixed-plan queries in {st.batches} batches "
          f"(mean occupancy {st.mean_occupancy:.1f}): "
          f"p50 {st.p50_ms:.1f} ms  p95 {st.p95_ms:.1f} ms  "
          f"p99 {st.p99_ms:.1f} ms")
    print(f"compiled executables: {eng.compiled_plans} "
          f"(= {len(plans)} distinct plans, NOT {st.n} queries)")
    assert eng.compiled_plans == len(plans)
    bar = 160.0
    print(f"paper's web-real-time bar (<{bar:.0f} ms): "
          f"{'MET' if st.p99_ms < bar else 'missed'}")

    # live ingest: inject a burst of docs pairing two mid-frequency terms,
    # and watch the network change on the very next query (the burst makes
    # (a, b) the anchor's heaviest co-occurrence, so it must enter the net)
    ranks = np.argsort(-df)
    a, b = int(ranks[300]), int(ranks[900])
    spec = QuerySpec(seeds=(a,), depth=2, topk=12, beam=16)
    key = (min(a, b), max(a, b))
    before = eng.submit(spec).result()
    eng.ingest_docs([[a, b]] * 80)
    after = eng.submit(spec).result()
    w0, w1 = before.edges().get(key, 0), after.edges().get(key, 0)
    print(f"edge ({a},{b}) weight: {w0} -> {w1} after ingesting 80 fresh "
          f"docs (epoch {before.epoch} -> {after.epoch})")
    assert w1 >= w0 + 80
    assert eng.compiled_plans == len(plans)      # ingest didn't add a plan
    print("real-time ingest visible to the next query  [ok]")

    # the string-level facade: same engine machinery behind text in/out
    idx = CoocIndex.from_texts(
        ["inverted index serves real time queries",
         "co-occurrence networks from an inverted index",
         "real time ingest keeps the index fresh"],
        depth=2, topk=8, beam=8)
    print("\nCoocIndex over a toy text corpus:")
    for s, d, w in idx.top(["index"], limit=5):
        print(f"  {s:>14} -- {d:<14} (co-occurs in {w} docs)")
    idx.add_documents(["fresh documents arrive and the index answers"])
    assert "arrive" in idx
    print("facade ingest-then-query round trip  [ok]")


if __name__ == "__main__":
    main()
