"""The paper's target scenario: a real-time co-occurrence query service.

    PYTHONPATH=src python examples/serve_realtime.py

Stands up CoocService over a CSL-scale-shaped corpus, serves a burst of
queries (latency percentiles vs the paper's 0.16 s web bar), then ingests
fresh documents and shows the next query reflecting them immediately —
the "real-time and dynamic characteristics" the paper motivates.  Finally
serves the same burst through the micro-batched CoocEngine (one jitted
batch per step, shared QueryContext cache) — the production serving path.
"""
import numpy as np

from repro.data import synthetic_csl
from repro.serve import CoocEngine, CoocService


def main():
    vocab, n_docs = 2048, 10000
    docs = synthetic_csl(n_docs, vocab, seed=0)
    svc = CoocService(docs, vocab, capacity=n_docs + 4096, depth=2,
                      topk=12, beam=16, engine="host")

    df = np.bincount(np.concatenate([np.unique(d) for d in docs]),
                     minlength=vocab)
    hot = np.argsort(-df)[:32]

    for t in hot:
        svc.query([int(t)])
    st = svc.stats()
    print(f"{st.n} queries: p50 {st.p50_ms:.1f} ms  p95 {st.p95_ms:.1f} ms  "
          f"p99 {st.p99_ms:.1f} ms  max {st.max_ms:.1f} ms")
    bar = 160.0
    print(f"paper's web-real-time bar (<{bar:.0f} ms): "
          f"{'MET' if st.p99_ms < bar else 'missed'}")

    # live ingest: inject a burst of docs pairing two mid-frequency terms,
    # and watch the network change on the very next query (the burst makes
    # (a, b) the anchor's heaviest co-occurrence, so it must enter the net)
    ranks = np.argsort(-df)
    a, b = int(ranks[300]), int(ranks[900])
    before = svc.query([a]).get((min(a, b), max(a, b)), 0)
    svc.ingest_docs([[a, b]] * 80)
    after = svc.query([a]).get((min(a, b), max(a, b)), 0)
    print(f"edge ({a},{b}) weight: {before} -> {after} after ingesting 80 "
          f"fresh docs (real-time visibility)")
    assert after >= before + 80
    print("real-time ingest visible to the next query  [ok]")

    # the production path: micro-batched engine over the service's own
    # (already up-to-date) context — no re-pack, shared incidence cache
    ctx = svc.ctx
    eng = CoocEngine(ctx, depth=2, topk=12, beam=16, q_batch=8)
    for t in hot:
        eng.submit([int(t)])
    eng.run_until_drained()
    est = eng.stats()
    print(f"engine: {est.n} queries in {est.batches} batches "
          f"(mean occupancy {est.mean_occupancy:.1f}), p50 {est.p50_ms:.1f} ms; "
          f"incidence unpacked {ctx.unpack_count}x for the whole burst")
    check = eng.query([a]).get((min(a, b), max(a, b)), 0)
    assert check == after, (check, after)
    print("engine results match the service path  [ok]")


if __name__ == "__main__":
    main()
