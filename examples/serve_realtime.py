"""The paper's target scenario, at service grade: an async multi-tenant
co-occurrence serving front end under real-time load.

    PYTHONPATH=src python examples/serve_realtime.py

Stands up a CoocServer over a CSL-scale-shaped corpus with two tenants —
"alpha" pinned to its own named scope of the shared index, "beta"
unscoped — and drives three phases:

1. a mixed-plan workload across both tenants (one engine, one compile per
   distinct executable — the bounded LRU compile cache underneath);
2. a burst far past the admission budget, showing explicit load shedding
   (typed "shed" responses, bounded queue depth) instead of unbounded
   queueing;
3. live ingest for the scoped tenant, visible to its very next query
   (the paper's "real-time and dynamic characteristics"), and invisible
   to the other tenant's scope.

Ends with the full metrics dump (per-tenant counters, p50/p99/p999,
shed/deadline-miss/eviction totals) and the string-level facade.
"""
import asyncio

import numpy as np

from repro.api import CoocIndex
from repro.core import QueryContext
from repro.data import synthetic_csl
from repro.serve import (
    AdmissionPolicy,
    CoocServer,
    ServerConfig,
    TenantConfig,
)


async def serve_demo():
    vocab, n_docs = 1024, 4000
    docs = synthetic_csl(n_docs, vocab, seed=0)
    ctx = QueryContext.from_docs(docs, vocab, capacity=n_docs + 2048)
    # "alpha" owns a scope over a slice of fresh docs; "beta" sees it all
    server = CoocServer(
        ctx,
        tenants=[TenantConfig("alpha", scope="alpha-docs"),
                 TenantConfig("beta")],
        config=ServerConfig(
            depth=2, topk=8, beam=16, q_batch=8, compile_budget=4,
            policy=AdmissionPolicy(max_queue_depth=32, max_wait_ms=30000.0),
            default_deadline_ms=60000.0, linger_ms=50.0))
    await server.start()
    await server.ingest("alpha", [[1, 2, 3, 4]] * 6, max_len=8)

    df = np.bincount(np.concatenate([np.unique(d) for d in docs]),
                     minlength=vocab)
    hot = [int(t) for t in np.argsort(-df)[:24]]

    # phase 1: mixed plans, both tenants, one engine underneath
    plans = [dict(depth=2, topk=8, beam=16),
             dict(depth=1, topk=12, beam=16)]
    reqs = [server.submit("alpha" if i % 3 == 0 else "beta",
                          dict(seeds=[t], **plans[i % 2]))
            for i, t in enumerate(hot)]
    responses = await asyncio.gather(*reqs)
    ok = sum(r.ok for r in responses)
    snap = server.snapshot()
    print(f"phase 1: {ok}/{len(responses)} mixed-plan queries served  "
          f"p50 {snap.latency.p50_ms:.0f} ms  p99 {snap.latency.p99_ms:.0f} ms"
          f"  compiled executables: {snap.compiled_plans}")
    assert ok == len(responses)
    assert snap.compiled_plans <= 4              # bounded by compile_budget

    # phase 2: a burst past the admission budget -> explicit shedding.
    # 120 concurrent submits against max_queue_depth=32: the policy sheds
    # the excess with typed responses; nothing queues unboundedly.
    burst = [server.submit("beta", [t]) for t in (hot * 5)]
    burst_resp = await asyncio.gather(*burst)
    shed = [r for r in burst_resp if r.status == "shed"]
    served = [r for r in burst_resp if r.ok]
    snap = server.snapshot()
    print(f"phase 2: burst of {len(burst_resp)} -> {len(served)} served, "
          f"{len(shed)} shed ({shed[0].reason if shed else '-'}), "
          f"peak queue depth {snap.peak_queue_depth}")
    assert shed, "burst should trip admission control"
    assert snap.peak_queue_depth <= 32           # bounded by construction
    assert all(r.ok or r.status == "shed" for r in burst_resp)

    # phase 3: real-time scoped ingest — alpha sees its fresh docs on the
    # next query; beta's unscoped view is the whole index either way
    a, b = 7, 11
    before = await server.submit("alpha", [a])
    await server.ingest("alpha", [[a, b]] * 40, max_len=8)
    after = await server.submit("alpha", [a])
    key = (min(a, b), max(a, b))
    w0 = before.result.edges().get(key, 0) if before.ok else 0
    w1 = after.result.edges().get(key, 0)
    print(f"phase 3: alpha edge ({a},{b}) weight {w0} -> {w1} after "
          f"ingesting 40 scoped docs")
    assert after.ok and w1 >= w0 + 40

    print("\nmetrics dump:")
    print(server.render_metrics())
    final = server.snapshot()
    assert final.deadline_miss_total == 0
    assert final.tenants["alpha"].counters.ingested_docs == 46
    await server.stop()
    print("server drained and stopped  [ok]")


def main():
    asyncio.run(serve_demo())

    # the string-level facade: same engine machinery behind text in/out
    idx = CoocIndex.from_texts(
        ["inverted index serves real time queries",
         "co-occurrence networks from an inverted index",
         "real time ingest keeps the index fresh"],
        depth=2, topk=8, beam=8)
    print("\nCoocIndex over a toy text corpus:")
    for s, d, w in idx.top(["index"], limit=5):
        print(f"  {s:>14} -- {d:<14} (co-occurs in {w} docs)")
    idx.add_documents(["fresh documents arrive and the index answers"])
    assert "arrive" in idx
    print("facade ingest-then-query round trip  [ok]")


if __name__ == "__main__":
    main()
