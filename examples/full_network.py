"""Whole-corpus network materialization + global statistics.

    PYTHONPATH=src python examples/full_network.py

The BFS query path answers "what co-occurs around THIS term"; the paper's
corpus-level experiments need the WHOLE network.  This example:

1. builds a string-level CoocIndex and materializes the full network —
   every term's top-k heaviest neighbors, computed tile-by-tile (never
   the (V, V) matrix),
2. prints the global statistics downstream network analysis reports
   (nodes, edges, density, degree distribution),
3. cross-checks the materialized rows against the exact traversal counts,
4. scopes the materialization to one source tag, then ingests fresh
   documents and watches the cached network invalidate and rebuild.
"""
import numpy as np

from repro.api import CoocIndex
from repro.core import degree_histogram, traversal_construct_host
from repro.data import build_lexicon

CORPUS = [
    "graph neural networks learn node embeddings from graph structure",
    "co-occurrence networks reveal semantic relationships in text corpora",
    "inverted index maps keywords to documents for fast retrieval",
    "breadth first search expands the network frontier level by level",
    "keyword co-occurrence networks support text mining and retrieval",
    "the inverted index makes co-occurrence network construction fast",
    "semantic networks and knowledge graphs organise scientific keywords",
    "fast retrieval of documents uses the inverted index keywords",
    "text mining extracts keywords and builds co-occurrence networks",
    "network construction from an inverted index runs in real time",
]


def main():
    idx = CoocIndex.from_texts(CORPUS)
    print(f"corpus: {idx.n_docs} docs, lexicon {idx.n_terms} terms")

    # 1. the whole-corpus artifact: top-4 neighbors per term, string edges
    net = idx.full_network(k=4)
    print(f"full network (k=4): {len(net)} unique undirected edges")

    # 2. the global statistics (the Fig.-style numbers)
    st = idx.network_stats(k=4)
    print(f"nodes {st.n_nodes}, edges {st.n_edges}, "
          f"density {st.density:.3f}, mean degree {st.mean_degree:.1f}, "
          f"max degree {st.max_degree}")
    hist = degree_histogram(st)
    print("degree distribution:",
          {g: int(c) for g, c in enumerate(hist) if c})

    # 3. every materialized weight equals the exact traversal pair count
    lex, docs = build_lexicon(CORPUS)
    trav = traversal_construct_host(docs, len(lex))
    for (a, b), w in net.items():
        key = (min(lex.lookup(a), lex.lookup(b)),
               max(lex.lookup(a), lex.lookup(b)))
        assert trav.get(key) == w, (a, b, w)
    print("all edge weights match the exact traversal counts  [ok]")

    heaviest = sorted(net.items(), key=lambda kv: -kv[1])[:5]
    print("\nheaviest corpus-level edges:")
    for (a, b), w in heaviest:
        print(f"  {a:>14} -- {b:<14} (co-occurs in {w} docs)")

    # 4. scoped materialization + real-time invalidation
    idx.add_documents(["quasar telescope survey maps the quasar sky"] * 2,
                      source="astro")
    astro = idx.full_network(k=4, scope="astro")
    assert all("quasar" in e or "telescope" in e or "survey" in e
               or "sky" in e or "maps" in e for e in astro)
    print(f"\nscoped to source='astro': {len(astro)} edges "
          f"(only the tagged docs)")
    grown = idx.full_network(k=4)
    assert ("quasar", "telescope") in grown
    print("after ingest the cached full network rebuilt "
          f"({len(grown)} edges) — real-time visibility  [ok]")

    assert np.all(st.degree >= 0)


if __name__ == "__main__":
    main()
